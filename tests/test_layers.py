"""Unit + property tests for core layers (attention, MoE, MLA, RoPE, loss)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep guard

from repro.configs import get_config
from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig
from repro.models.spec import init_params as spec_init


def _dense_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), shift=st.integers(1, 50))
def test_rope_relative_property(seed, shift):
    """RoPE: <rot(q,p1), rot(k,p2)> depends only on p1-p2."""
    key = jax.random.PRNGKey(seed)
    kq, kk = jax.random.split(key)
    q = jax.random.normal(kq, (1, 1, 1, 64))
    k = jax.random.normal(kk, (1, 1, 1, 64))
    def dot(p1, p2):
        qr = L.apply_rope(q, jnp.array([[p1]]))
        kr = L.apply_rope(k, jnp.array([[p2]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot(5, 3) - dot(5 + shift, 3 + shift)) < 1e-3


def test_rope_norm_preserving(rng_key):
    x = jax.random.normal(rng_key, (2, 8, 4, 32))
    xr = L.apply_rope(x, jnp.arange(8)[None].repeat(2, 0))
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(x, axis=-1)),
                               np.asarray(jnp.linalg.norm(xr, axis=-1)), rtol=1e-5)


def test_rms_norm(rng_key):
    x = 5 + 3 * jax.random.normal(rng_key, (4, 16))
    y = L.rms_norm(x, jnp.ones((16,)))
    rms = np.asarray(jnp.sqrt(jnp.mean(jnp.square(y), -1)))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_attention_causality(rng_key):
    cfg = _dense_cfg()
    p = spec_init(L.attention_spec(cfg), rng_key)
    x = jax.random.normal(rng_key, (1, 16, 64))
    pos = jnp.arange(16)
    y1 = L.self_attention(p, x, pos, cfg)
    x2 = x.at[:, 10:].set(3.0)
    y2 = L.self_attention(p, x2, pos, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :10]), np.asarray(y2[:, :10]),
                               atol=1e-5)


def test_chunked_equals_dense_attention(rng_key):
    cfg = _dense_cfg()
    p = spec_init(L.attention_spec(cfg), rng_key)
    x = jax.random.normal(rng_key, (2, 100, 64))
    pos = jnp.arange(100)
    y_dense = L.self_attention(p, x, pos, cfg, attn_impl="full")
    y_chunk = L.self_attention(p, x, pos, cfg, attn_impl="chunked")
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_chunk),
                               atol=2e-5, rtol=2e-5)


def test_gqa_matches_repeated_mha(rng_key):
    """GQA == MHA with kv heads repeated G times."""
    B, S, H, KV, hd = 1, 12, 4, 2, 16
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    bias = jnp.zeros((B, 1, S, S), jnp.float32)
    out_gqa = L.gqa_attend(q, k, v, bias)
    k_rep = jnp.repeat(k, H // KV, axis=2)
    v_rep = jnp.repeat(v, H // KV, axis=2)
    out_mha = L.gqa_attend(q, k_rep, v_rep, bias)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5)


def test_moe_combine_weights_and_aux(rng_key):
    cfg = _dense_cfg(family="moe",
                     moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                                   num_shared=1, capacity_factor=8.0))
    p = spec_init(L.moe_spec(cfg), rng_key)
    x = jax.random.normal(rng_key, (2, 8, 64))
    y, aux = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0
    # aux ~ E * sum f_e p_e * w; with 4 experts and balanced routing ~ w
    assert float(aux) < 10 * cfg.moe.router_aux_weight * cfg.moe.num_experts


def test_moe_capacity_dropping(rng_key):
    """With capacity_factor -> 0+, (almost) everything is dropped and the
    output reduces to the shared-expert path."""
    moe = MoEConfig(num_experts=4, top_k=1, d_ff_expert=32, num_shared=1,
                    capacity_factor=1e-9)
    cfg = _dense_cfg(family="moe", moe=moe)
    p = spec_init(L.moe_spec(cfg), rng_key)
    x = jax.random.normal(rng_key, (1, 8, 64))
    y, _ = L.moe_apply(p, x, cfg)
    shared_only = L.mlp_apply(p["shared"], x, gated=True)
    # capacity >= 1 always, so exactly one token per expert survives; check
    # that at least the majority of rows equal the shared path
    diff = np.asarray(jnp.max(jnp.abs(y - shared_only), axis=-1))[0]
    assert (diff < 1e-5).sum() >= 4


def test_mla_decode_cache_is_compressed(rng_key):
    cfg = get_config("deepseek-v2-236b")
    shp = L.mla_cache_shape(cfg, batch=1, cache_len=1000)
    per_token = shp["c_kv"][-1] + shp["k_rope"][-1]
    assert per_token == cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim  # 576
    # vs uncompressed 2*H*hd = 2*128*192 -> ~85x compression
    assert per_token * 40 < 2 * cfg.num_heads * (cfg.mla.nope_head_dim
                                                 + cfg.mla.rope_head_dim)


def test_cross_entropy_matches_manual(rng_key):
    logits = jax.random.normal(rng_key, (2, 5, 11))
    labels = jax.random.randint(rng_key, (2, 5), 0, 11)
    got = float(L.cross_entropy_loss(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = -float(jnp.mean(jnp.take_along_axis(p, labels[..., None], -1)))
    assert abs(got - want) < 1e-5
