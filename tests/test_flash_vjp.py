"""flash_mha custom-VJP validation: forward AND gradients vs the dense
reference, across causal/window/GQA/MLA-style (hd_v != hd) cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _mask_bias, flash_mha, gqa_attend

CASES = [
    # B, S, H, KV, hd, hd_v, causal, window
    (2, 200, 4, 2, 32, 32, True, None),
    (1, 150, 4, 4, 64, 64, True, 40),
    (1, 130, 6, 3, 32, 32, False, None),
    (1, 100, 2, 1, 64, 32, True, None),  # MLA-style: v head dim differs
]


@pytest.mark.parametrize("case", CASES)
def test_flash_mha_fwd_and_grads(case, rng_key):
    B, S, H, KV, hd, hdv, causal, win = case
    ks = jax.random.split(rng_key, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hdv))
    do = jax.random.normal(ks[3], (B, S, H, hdv))
    pos = jnp.arange(S)

    def dense(q, k, v):
        bias = _mask_bias(pos, pos, causal, win)[None, None]
        return gqa_attend(q, k, v, bias)

    def flash(q, k, v):
        return flash_mha(q, k, v, pos, pos, causal, win, 64, 64)

    np.testing.assert_allclose(np.asarray(flash(q, k, v)),
                               np.asarray(dense(q, k, v)), atol=5e-5)
    g_d = jax.grad(lambda *a: jnp.sum(dense(*a) * do), argnums=(0, 1, 2))(q, k, v)
    g_f = jax.grad(lambda *a: jnp.sum(flash(*a) * do), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_d, g_f):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=5e-4)


def test_flash_mha_used_in_model_grads(rng_key):
    """End-to-end: a model with seq > threshold trains through flash_mha."""
    import dataclasses
    from repro.models import layers as L
    from repro.configs import get_config
    from repro.models.spec import init_params as spec_init

    cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(), dtype="float32")
    p = spec_init(L.attention_spec(cfg), rng_key)
    S = L.CHUNKED_ATTN_THRESHOLD + 64
    x = 0.1 * jax.random.normal(rng_key, (1, S, cfg.d_model))
    pos = jnp.arange(S)

    def f(pp):
        return jnp.sum(L.self_attention(pp, x, pos, cfg) ** 2)

    g = jax.grad(f)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
